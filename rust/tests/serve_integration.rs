//! Integration: the threaded serving system against real artifacts —
//! request lifecycle, continuous batching, both scheduling modes, clean
//! shutdown under load, and N-tier fleets with replicated workers.

use std::path::{Path, PathBuf};
use std::time::Duration;

use hybrid_llm::batching::BatchMode;
use hybrid_llm::corpus::{generate, Scale, Split};
use hybrid_llm::lm::LmEngine;
use hybrid_llm::policy::TierPolicy;
use hybrid_llm::runtime::Runtime;
use hybrid_llm::serve::{ReplicaSelect, ServeConfig, Server, TierSpec};

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.txt").exists().then_some(p)
}

fn seed_run_dir(artifacts: &Path, tag: &str) -> PathBuf {
    let run = std::env::temp_dir().join(format!("hybrid_serve_{}_{tag}", std::process::id()));
    let rt = Runtime::load(artifacts).unwrap();
    for model in ["nano", "micro"] {
        let dir = run.join("params").join(model);
        if !dir.join("p.emb.tz").exists() {
            let eng = LmEngine::init(rt.clone(), model, 3).unwrap();
            eng.save(&dir).unwrap();
        }
    }
    run
}

fn base_cfg(artifacts: PathBuf, run_dir: PathBuf, mode: BatchMode) -> ServeConfig {
    // random routing (no trained router needed) over the seed pair
    let mut cfg = ServeConfig::two_tier(artifacts, run_dir, "nano", "micro", String::new(), 0.5);
    cfg.temp = 0.8;
    cfg.mode = mode;
    cfg.batch_window = Duration::from_millis(2);
    cfg
}

#[test]
fn serves_all_requests_continuous() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "cont");
    let server =
        Server::start(base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous)).unwrap();
    let corpus = generate(3, Scale::Smoke);
    let reqs: Vec<_> = corpus
        .iter()
        .filter(|q| q.split == Split::Test)
        .take(24)
        .collect();
    let rxs: Vec<_> = reqs.iter().map(|q| server.submit(q.prompt.clone())).collect();
    let mut ids = std::collections::HashSet::new();
    let mut small = 0;
    for rx in rxs {
        let c = rx.recv_timeout(Duration::from_secs(120)).expect("completion");
        assert!(ids.insert(c.id), "duplicate completion id");
        assert!(c.tokens.len() < hybrid_llm::corpus::A_MAX);
        assert!((0.0..=1.0).contains(&c.router_score));
        if c.tier == 0 {
            small += 1;
        }
    }
    assert_eq!(ids.len(), 24, "every request completed exactly once");
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.total(), 24);
    assert_eq!(stats.routing.to_small() as usize, small);
    assert!(stats.decode_steps > 0);
    assert_eq!(stats.e2e_latency.n, 24);
    // per-tier latency counts partition the e2e count
    assert_eq!(stats.tiers.len(), 2);
    assert_eq!(stats.tiers.iter().map(|t| t.latency.n).sum::<usize>(), 24);

    // residency acceptance: with v2 (untupled) artifacts the steady-state
    // decode downloads O(B) bytes per step — the sampled tokens and
    // logprobs — never the O(L·B·S·H·Dh) KV pair the seed round-tripped.
    let rt = Runtime::load(&artifacts).unwrap();
    if rt.manifest.version >= 2 {
        let g = rt.manifest.globals;
        let kv_pair_bytes = ["nano", "micro"]
            .iter()
            .map(|m| {
                let meta = *rt.manifest.model(m).unwrap();
                (2 * meta.layers * g.genb * g.sctx * meta.heads * meta.headdim * 4) as f64
            })
            .fold(f64::MAX, f64::min);
        assert!(
            stats.d2h_bytes_per_step() < kv_pair_bytes / 4.0,
            "decode downloads {:.0} B/step — KV caches are round-tripping \
             (smallest pair = {kv_pair_bytes:.0} B)",
            stats.d2h_bytes_per_step()
        );
        // uploads are O(B) too: the post-surgery KV re-upload is part of
        // the admission window, not the decode loop
        assert!(
            stats.h2d_bytes_per_step() < kv_pair_bytes / 4.0,
            "decode uploads {:.0} B/step — KV caches are round-tripping",
            stats.h2d_bytes_per_step()
        );
    }
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn shutdown_under_load_drains_every_request() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "drain");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous)).unwrap();
    let corpus = generate(13, Scale::Smoke);
    // submit a burst and shut down immediately, while the router is still
    // dispatching and the workers still decoding: the drain protocol
    // (join router before signalling workers) must deliver every
    // completion instead of erroring with "worker channel closed"
    let rxs: Vec<_> = corpus
        .iter()
        .take(30)
        .map(|q| server.submit(q.prompt.clone()))
        .collect();
    let stats = server.shutdown().expect("graceful shutdown under load");
    assert_eq!(stats.e2e_latency.n, 30, "all in-flight requests completed");
    let mut ids = std::collections::HashSet::new();
    for rx in rxs {
        let c = rx.try_recv().expect("completion delivered before shutdown returned");
        assert!(ids.insert(c.id));
    }
    assert_eq!(ids.len(), 30);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn device_and_host_kv_decode_identical_tokens() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&artifacts).unwrap();
    let eng = LmEngine::init(rt.clone(), "nano", 3).unwrap();
    let corpus = generate(17, Scale::Smoke);
    let g = rt.manifest.globals;
    let prompts: Vec<&[i32]> = corpus
        .iter()
        .take(g.genb)
        .map(|q| q.prompt.as_slice())
        .collect();
    let seeds: Vec<u32> = (0..prompts.len() as u32).collect();
    // sampled (temp > 0) so any divergence in the KV contents would
    // surface as different tokens almost immediately
    let dev = eng.generate_with(&prompts, &seeds, 0.8, false).unwrap();
    let host = eng.generate_with(&prompts, &seeds, 0.8, true).unwrap();
    assert_eq!(dev.len(), host.len());
    for (b, (d, h)) in dev.iter().zip(&host).enumerate() {
        assert_eq!(d.tokens, h.tokens, "slot {b}: residency changed the decode");
        assert!(
            (d.mean_logprob - h.mean_logprob).abs() < 1e-6,
            "slot {b}: logprobs diverged"
        );
    }
}

#[test]
fn serves_all_requests_run_to_completion() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "rtc");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::RunToCompletion)).unwrap();
    let corpus = generate(5, Scale::Smoke);
    let rxs: Vec<_> = corpus
        .iter()
        .take(20)
        .map(|q| server.submit(q.prompt.clone()))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(120)).expect("completion");
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.e2e_latency.n, 20);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn shutdown_with_no_traffic_is_clean() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "idle");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous)).unwrap();
    std::thread::sleep(Duration::from_millis(100));
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.total(), 0);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn threshold_extremes_route_everything_one_way() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "thr");
    // threshold 0.0 => every score >= 0 => all small
    let mut cfg = base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous);
    cfg.policy = TierPolicy::Ladder { thresholds: vec![0.0] };
    let server = Server::start(cfg).unwrap();
    let corpus = generate(7, Scale::Smoke);
    let rxs: Vec<_> = corpus
        .iter()
        .take(8)
        .map(|q| server.submit(q.prompt.clone()))
        .collect();
    for rx in rxs {
        let c = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(c.tier, 0, "everything must route to the small tier");
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.to_large(), 0);
    assert!((stats.routing.cost_advantage - 1.0).abs() < 1e-9);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn three_tier_fleet_with_replicas_serves() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "fleet");
    // device/edge/cloud fleet over the two seeded models, with a
    // replicated bottom tier and shortest-queue replica selection
    let mut cfg = base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous);
    cfg.tiers = vec![
        TierSpec::named("device", "nano", 2, 0.0),
        TierSpec::named("edge", "nano", 1, 0.4),
        TierSpec::named("cloud", "micro", 1, 1.0),
    ];
    cfg.policy = TierPolicy::even_ladder(3);
    cfg.select = ReplicaSelect::ShortestQueue;
    let server = Server::start(cfg).unwrap();
    let corpus = generate(9, Scale::Smoke);
    let rxs: Vec<_> = corpus
        .iter()
        .take(18)
        .map(|q| server.submit(q.prompt.clone()))
        .collect();
    let mut by_tier = [0usize; 3];
    for rx in rxs {
        let c = rx.recv_timeout(Duration::from_secs(180)).expect("completion");
        assert!(c.tier < 3);
        by_tier[c.tier] += 1;
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.total(), 18);
    assert_eq!(stats.tiers.len(), 3);
    assert_eq!(stats.routing.tiers.len(), 3);
    for (i, tr) in stats.routing.tiers.iter().enumerate() {
        assert_eq!(tr.routed as usize, by_tier[i], "tier {} count mismatch", tr.name);
    }
    assert_eq!(stats.routing.tiers[0].name, "device");
    assert_eq!(stats.routing.tiers[2].name, "cloud");
    // per-tier latencies partition e2e completions
    assert_eq!(stats.tiers.iter().map(|t| t.latency.n).sum::<usize>(), 18);
    assert_eq!(stats.e2e_latency.n, 18);
    let _ = std::fs::remove_dir_all(&run_dir);
}
