//! Integration: load + execute real AOT artifacts through PJRT.
//!
//! These tests require `make artifacts` to have run; they skip (with a
//! note) when the artifacts directory is absent so `cargo test` stays
//! usable in a fresh checkout.

use std::path::{Path, PathBuf};

use hybrid_llm::batching::KvCache;
use hybrid_llm::io::Tensor;
use hybrid_llm::runtime::{bucket_for, Runtime};

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.txt").exists().then_some(p)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn init_artifact_runs_and_is_seed_deterministic() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let exec = rt.exec("nano.init").unwrap();
    let seed = Tensor::u32(vec![], vec![7]);
    let out1 = exec.run(&[&seed]).unwrap();
    let out2 = exec.run(&[&seed]).unwrap();
    assert_eq!(out1.len(), exec.spec.outs.len());
    assert_eq!(out1[0], out2[0]);
    // emb is [VOCAB, d]
    assert_eq!(out1[0].dims()[0], 64);
    let other = exec.run(&[&Tensor::u32(vec![], vec![8])]).unwrap();
    assert_ne!(out1[0], other[0]);
}

#[test]
fn router_fwd_scores_in_unit_interval() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let init = rt.exec("router.init").unwrap();
    let params = init.run(&[&Tensor::u32(vec![], vec![0])]).unwrap();
    let fwd = rt.exec("router.fwd").unwrap();
    let g = rt.manifest.globals;
    let b = g.trainb;
    let mut tokens = vec![0i32; b * g.sprompt];
    for s in tokens.iter_mut().step_by(g.sprompt) {
        *s = 1; // BOS
    }
    let toks = Tensor::i32(vec![b, g.sprompt], tokens);
    let lens = Tensor::i32(vec![b], vec![1; b]);
    let mut ins: Vec<&Tensor> = params.iter().collect();
    ins.push(&toks);
    ins.push(&lens);
    let out = fwd.run(&ins).unwrap();
    let scores = out[0].as_f32().unwrap();
    assert_eq!(scores.len(), b);
    for &s in scores {
        assert!(s > 0.0 && s < 1.0, "{s}");
    }
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let exec = rt.exec("nano.init").unwrap();
    // wrong dtype
    assert!(exec.run(&[&Tensor::i32(vec![], vec![7])]).is_err());
    // wrong count
    assert!(exec.run(&[]).is_err());
}

#[test]
fn resident_params_execute_matches_literal_path() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let init = rt.exec("nano.init").unwrap();
    let params = init.run(&[&Tensor::u32(vec![], vec![3])]).unwrap();

    let g = rt.manifest.globals;
    let fwd = rt.exec("nano.prefill1").unwrap();
    let mut prompt = vec![0i32; g.sprompt];
    prompt[0] = 1;
    prompt[1] = 40;
    prompt[2] = 50;
    prompt[3] = 9;
    prompt[4] = 3;
    let prompt = Tensor::i32(vec![1, g.sprompt], prompt);
    let lens = Tensor::i32(vec![1], vec![5]);
    let seeds = Tensor::u32(vec![1], vec![0]);
    let temp = Tensor::f32(vec![], vec![0.0]);

    // literal path
    let mut ins: Vec<&Tensor> = params.iter().collect();
    ins.extend([&prompt, &lens, &seeds, &temp]);
    let out_lit = fwd.run(&ins).unwrap();

    // resident path
    let mut resident = std::collections::HashMap::new();
    for (i, p) in params.iter().enumerate() {
        resident.insert(i, rt.upload(p).unwrap());
    }
    let n = params.len();
    let host: Vec<(usize, &Tensor)> = vec![
        (n, &prompt),
        (n + 1, &lens),
        (n + 2, &seeds),
        (n + 3, &temp),
    ];
    let out_res = fwd.run_with_resident(&resident, &host).unwrap();

    assert_eq!(out_lit[0], out_res[0], "sampled token must match");
    assert_eq!(out_lit[2], out_res[2], "kcache must match");
}

/// Manifest v3: the `kv_install@B` scatter must (a) produce a cache
/// byte-identical to host-side slot surgery over the same prefill
/// outputs — including masking the bucket's padding entries — and
/// (b) move only the O(B) slot/count bytes across the host boundary.
#[test]
fn kv_install_matches_host_surgery_and_moves_o_b_bytes() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    if rt.manifest.version < 3 {
        eprintln!("pre-v3 artifacts: no device-side admission to test");
        return;
    }
    let g = rt.manifest.globals;
    let meta = *rt.manifest.model("nano").unwrap();
    let init = rt.exec("nano.init").unwrap();
    let params = init.run(&[&Tensor::u32(vec![], vec![3])]).unwrap();
    let n = params.len();
    let mut resident = std::collections::HashMap::new();
    for (i, p) in params.iter().enumerate() {
        resident.insert(i, rt.upload(p).unwrap());
    }

    // three requests -> bucket 4: entry 3 is padding whose install must
    // be masked out whatever garbage its prefill row carries
    let n_req = 3usize;
    let buckets = rt.manifest.prefill_buckets("nano");
    let b = bucket_for(&buckets, n_req).expect("v3 manifests carry prefill buckets");
    assert!(b >= n_req && b < g.genb, "bucket {b} for {n_req} requests");
    let prefill = rt.exec(&format!("nano.prefill@{b}")).unwrap();
    let mut prompt = vec![0i32; b * g.sprompt];
    for (i, r) in prompt.chunks_mut(g.sprompt).enumerate() {
        r[0] = 1;
        r[1] = 9 + i as i32;
        r[2] = 4;
    }
    let prompt = Tensor::i32(vec![b, g.sprompt], prompt);
    let lens = Tensor::i32(vec![b], vec![3; b]);
    let seeds = Tensor::u32(vec![b], (0..b as u32).collect());
    let temp = Tensor::f32(vec![], vec![0.0]);
    let host: Vec<(usize, &Tensor)> = vec![
        (n, &prompt),
        (n + 1, &lens),
        (n + 2, &seeds),
        (n + 3, &temp),
    ];
    let mut outs = prefill.run_resident(&resident, &host).unwrap();
    let vc = outs.pop().unwrap();
    let kc = outs.pop().unwrap();
    let (kb, vb) = (
        kc.device().expect("v3 prefill kcache stays on device").clone(),
        vc.device().expect("v3 prefill vcache stays on device").clone(),
    );

    // device path: scatter into a zeroed device-resident cache
    let slots = [5usize, 0, 9];
    let install = rt.exec(&format!("nano.kv_install@{b}")).unwrap();
    let mut dev = KvCache::zeros(meta.layers, g.genb, g.sctx, meta.heads, meta.headdim);
    dev.to_device(&rt).unwrap(); // startup upload, outside the metered window
    let before = rt.transfers();
    dev.install_slots_device(&rt, &install, &kb, &vb, &slots).unwrap();
    let moved = before.delta(rt.transfers());
    assert!(dev.is_device(), "install must keep the cache on device");
    assert_eq!(moved.d2h_bytes, 0, "install downloaded {} B", moved.d2h_bytes);
    assert!(
        moved.h2d_bytes < 1024,
        "install uploaded {} B — O(B) slot indices expected",
        moved.h2d_bytes
    );

    // host-surgery reference over the same prefill outputs
    let bucket_dims = vec![meta.layers, b, g.sctx, meta.heads, meta.headdim];
    let mut fresh = KvCache::from_outputs(kc, vc, &bucket_dims).unwrap();
    fresh.to_host(&rt).unwrap();
    let mut reference = KvCache::zeros(meta.layers, g.genb, g.sctx, meta.heads, meta.headdim);
    for (i, &s) in slots.iter().enumerate() {
        reference.copy_slot_from(&fresh, i, s).unwrap();
    }

    dev.to_host(&rt).unwrap();
    let (dk, dv) = dev.host_tensors().unwrap();
    let (rk, rv) = reference.host_tensors().unwrap();
    assert_eq!(dk, rk, "device-installed kcache != host surgery");
    assert_eq!(dv, rv, "device-installed vcache != host surgery");
}

#[test]
fn run_resident_keeps_state_outputs_on_device() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).unwrap();
    let g = rt.manifest.globals;
    let init = rt.exec("nano.init").unwrap();
    let params = init.run(&[&Tensor::u32(vec![], vec![3])]).unwrap();
    let n = params.len();
    let mut resident = std::collections::HashMap::new();
    for (i, p) in params.iter().enumerate() {
        resident.insert(i, rt.upload(p).unwrap());
    }

    let prefill = rt.exec("nano.prefill").unwrap();
    let mut prompt = vec![0i32; g.genb * g.sprompt];
    for r in prompt.chunks_mut(g.sprompt) {
        r[0] = 1;
        r[1] = 9;
    }
    let prompt = Tensor::i32(vec![g.genb, g.sprompt], prompt);
    let lens = Tensor::i32(vec![g.genb], vec![2; g.genb]);
    let seeds = Tensor::u32(vec![g.genb], vec![0; g.genb]);
    let temp = Tensor::f32(vec![], vec![0.0]);
    let host: Vec<(usize, &Tensor)> = vec![
        (n, &prompt),
        (n + 1, &lens),
        (n + 2, &seeds),
        (n + 3, &temp),
    ];
    let mut outs = prefill.run_resident(&resident, &host).unwrap();
    assert_eq!(outs.len(), 4);
    let vc = outs.pop().unwrap();
    let kc = outs.pop().unwrap();
    let logp = outs.pop().unwrap();
    let next = outs.pop().unwrap();
    // data outputs always come back on the host
    assert!(!next.is_device());
    assert!(!logp.is_device());
    if rt.manifest.version < 2 {
        eprintln!("pre-v2 artifacts: host fallback path (all outputs downloaded)");
        assert!(!kc.is_device() && !vc.is_device());
        return;
    }
    // v2 untupled artifacts: KV caches stay device-resident, and a decode
    // step fed from them downloads O(B) bytes, not the O(L·B·S·H·Dh) pair
    assert!(kc.is_device(), "kcache must stay on device");
    assert!(vc.is_device(), "vcache must stay on device");

    let decode = rt.exec("nano.decode").unwrap();
    let mut res2 = resident.clone();
    res2.insert(n, kc.device().unwrap().clone());
    res2.insert(n + 1, vc.device().unwrap().clone());
    let tok = Tensor::i32(vec![g.genb], vec![5; g.genb]);
    let pos = Tensor::i32(vec![g.genb], vec![2; g.genb]);
    let step = Tensor::i32(vec![], vec![1]);
    let host: Vec<(usize, &Tensor)> = vec![
        (n + 2, &tok),
        (n + 3, &pos),
        (n + 4, &step),
        (n + 5, &seeds),
        (n + 6, &temp),
    ];
    let before = rt.transfers();
    let outs = decode.run_resident(&res2, &host).unwrap();
    let moved = before.delta(rt.transfers());
    assert!(outs[2].is_device() && outs[3].is_device());
    let meta = *rt.manifest.model("nano").unwrap();
    let kv_pair_bytes =
        (2 * meta.layers * g.genb * g.sctx * meta.heads * meta.headdim * 4) as u64;
    assert!(
        moved.d2h_bytes < kv_pair_bytes / 4,
        "decode step downloaded {} B — KV caches are round-tripping (pair = {} B)",
        moved.d2h_bytes,
        kv_pair_bytes
    );
    assert!(
        moved.h2d_bytes < kv_pair_bytes / 4,
        "decode step uploaded {} B — KV caches are round-tripping",
        moved.h2d_bytes
    );
}
