//! Integration: the trace-replay scenario harness against real
//! artifacts — burst/cancel-storm/overload replays gated on the serving
//! invariants (exactly one terminal event per accepted request, counter
//! balance at drain, bounded queue, transfer bounds), plus the
//! stress-surfaced edge cases this PR fixed: stats snapshots before any
//! completion, zero token budgets, and prompts that fill the context
//! window. Pure generator properties run without artifacts.

use std::path::{Path, PathBuf};
use std::time::Duration;

use hybrid_llm::batching::BatchMode;
use hybrid_llm::lm::LmEngine;
use hybrid_llm::runtime::{Manifest, Runtime};
use hybrid_llm::scenario::{
    self, check_invariants, gen_cancel_storm, gen_overload, gen_poisson_burst, replay, GenShape,
    ReplayOpts, TransferBounds,
};
use hybrid_llm::serve::{
    Fault, FaultKind, FaultPlan, Request, ServeConfig, Server, ServerStats, SubmitError,
};
use hybrid_llm::testing::check;

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.txt").exists().then_some(p)
}

fn seed_run_dir(artifacts: &Path, tag: &str) -> PathBuf {
    let run = std::env::temp_dir().join(format!("hybrid_scenario_{}_{tag}", std::process::id()));
    let rt = Runtime::load(artifacts).unwrap();
    for model in ["nano", "micro"] {
        let dir = run.join("params").join(model);
        if !dir.join("p.emb.tz").exists() {
            let eng = LmEngine::init(rt.clone(), model, 3).unwrap();
            eng.save(&dir).unwrap();
        }
    }
    run
}

fn base_cfg(artifacts: PathBuf, run_dir: PathBuf) -> ServeConfig {
    // random routing (no trained router needed) over the tiny pair
    let mut cfg = ServeConfig::two_tier(artifacts, run_dir, "nano", "micro", String::new(), 0.5);
    cfg.temp = 0.8;
    cfg.mode = BatchMode::Continuous;
    cfg.batch_window = Duration::from_millis(2);
    cfg
}

fn shape_of(artifacts: &Path) -> (GenShape, Manifest) {
    let manifest = Manifest::load(&artifacts.join("manifest.txt")).unwrap();
    let g = manifest.globals;
    (GenShape { sprompt: g.sprompt, amax: g.amax }, manifest)
}

/// Property (no artifacts): every generator yields a valid trace for
/// arbitrary seeds, counts, and artifact shapes — sorted arrivals,
/// prompt lengths within the window, no zero token budgets (which
/// `submit` would reject).
#[test]
fn generators_always_yield_valid_traces() {
    check("scenario generators yield valid traces", 64, |rng| {
        let shape = GenShape {
            sprompt: rng.range(2, 64),
            amax: rng.range(2, 32),
        };
        let seed = rng.next_u64();
        let n = rng.range(1, 40);
        for gen in [
            scenario::gen_steady as fn(u64, usize, GenShape) -> scenario::Trace,
            gen_poisson_burst,
            scenario::gen_diurnal,
            scenario::gen_long_tail,
            scenario::gen_mixed_quality,
            gen_overload,
            gen_cancel_storm,
            scenario::gen_hybrid_decode,
            scenario::gen_overload_brownout,
        ] {
            let t = gen(seed, n, shape);
            assert_eq!(t.events.len(), n);
            assert!(t.events.windows(2).all(|w| w[0].at <= w[1].at));
            for e in &t.events {
                assert!(e.prompt_len >= 1 && e.prompt_len <= shape.sprompt.max(2));
                if let Some(m) = e.max_new {
                    assert!(m >= 1, "generated a zero token budget");
                }
                if let Some(q) = e.quality {
                    assert!((0.0..=1.0).contains(&q));
                }
            }
        }
    });
}

/// Property (no artifacts): trace text round-trips exactly for every
/// generator output.
#[test]
fn traces_roundtrip_through_text() {
    let dir = std::env::temp_dir().join(format!("hybrid_trace_rt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    check("trace text round-trip", 16, |rng| {
        let shape = GenShape { sprompt: 40, amax: 24 };
        let t = gen_cancel_storm(rng.next_u64(), rng.range(1, 30), shape);
        let path = dir.join("prop.trace");
        t.save(&path).unwrap();
        assert_eq!(scenario::Trace::load(&path).unwrap(), t);
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// A `Server::stats()` snapshot taken before any request completes (or
/// even arrives) must not panic and must report zeroed, NaN-free
/// latency summaries — the empty-window stats bug this PR fixed.
#[test]
fn stats_snapshot_before_first_completion() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "snap");
    let server = Server::start(base_cfg(artifacts, run_dir.clone())).unwrap();
    let stats = server.stats(); // no requests yet: all windows empty
    assert_eq!(stats.e2e_latency.n, 0);
    assert_eq!(stats.e2e_latency.p50_ms, 0.0);
    assert_eq!(stats.e2e_latency.p95_ms, 0.0);
    assert_eq!(stats.routing.total(), 0);
    assert_eq!(stats.in_flight, 0);
    server.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// `max_new_tokens(0)` is rejected at submit — not silently promoted to
/// one generated token as earlier revisions did.
#[test]
fn zero_token_budget_rejected_at_submit() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "zero");
    let server = Server::start(base_cfg(artifacts, run_dir.clone())).unwrap();
    let err = server
        .submit(Request::new(vec![4, 5, 6]).max_new_tokens(0))
        .expect_err("zero budget must be rejected");
    assert_eq!(err, SubmitError::ZeroTokenBudget);
    // a rejected request must not leak an admission slot
    assert_eq!(server.in_flight(), 0);
    // budget 1 is the smallest satisfiable request
    let h = server.submit(Request::new(vec![4, 5, 6]).max_new_tokens(1)).unwrap();
    let c = h.wait_timeout(Duration::from_secs(120)).expect("completion");
    assert!(c.tokens.len() <= 1);
    server.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// A prompt that fills the whole prompt window with an unbounded token
/// budget must complete cleanly at the context boundary: the training
/// layout reserves the final position for EOS, so at most `amax - 1`
/// tokens come back and nothing panics at `sctx`.
#[test]
fn prompt_fills_context_stops_at_the_boundary() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (shape, _) = shape_of(&artifacts);
    let run_dir = seed_run_dir(&artifacts, "full");
    let server = Server::start(base_cfg(artifacts, run_dir.clone())).unwrap();
    // temp 0.8 sampling rarely emits EOS early on random weights, so
    // these decodes actually reach the boundary stop
    let handles: Vec<_> = (0..4)
        .map(|i| {
            server
                .submit(
                    Request::new(scenario::synthetic_prompt(shape.sprompt, i))
                        .max_new_tokens(usize::MAX),
                )
                .expect("submit full-window prompt")
        })
        .collect();
    for h in handles {
        let c = h.wait_timeout(Duration::from_secs(120)).expect("completion");
        assert!(
            c.tokens.len() <= shape.amax - 1,
            "{} tokens breaches the reserved-EOS budget {}",
            c.tokens.len(),
            shape.amax - 1
        );
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.completed, 4);
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// The cancel-storm scenario: every accepted request gets exactly one
/// terminal event and the server counters balance at drain, with most
/// requests cancelled mid-flight.
#[test]
fn cancel_storm_invariants_hold() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (shape, manifest) = shape_of(&artifacts);
    let run_dir = seed_run_dir(&artifacts, "storm");
    let cfg = base_cfg(artifacts, run_dir.clone());
    let queue_cap = cfg.queue_cap as u64;
    let server = Server::start(cfg).unwrap();
    let trace = gen_cancel_storm(0xBAD5EED, 24, shape);
    let out = replay(&server, &trace, &ReplayOpts::default()).unwrap();
    let stats = server.shutdown().unwrap();
    let bounds = scenario::transfer_bounds(&manifest, &["nano", "micro"]).unwrap();
    let violations = check_invariants(&out, &stats, queue_cap, &bounds);
    assert!(violations.is_empty(), "cancel-storm violations: {violations:?}");
    assert_eq!(out.accepted, 24);
    assert_eq!(out.done + out.failed + out.cancelled, out.accepted);
    assert!(out.cancelled > 0, "a cancel storm should cancel something");
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// The overload scenario against a tiny admission window: Busy
/// backpressure engages, nothing exceeds the bound, and whatever was
/// accepted still resolves to exactly one terminal event with balanced
/// counters.
#[test]
fn overload_invariants_hold_with_small_window() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (shape, manifest) = shape_of(&artifacts);
    let run_dir = seed_run_dir(&artifacts, "over");
    let mut cfg = base_cfg(artifacts, run_dir.clone());
    cfg.queue_cap = 4;
    let server = Server::start(cfg).unwrap();
    let n = 32;
    let trace = gen_overload(0x0E7105D, n, shape);
    let opts = ReplayOpts { retry_busy: false, ..Default::default() };
    let out = replay(&server, &trace, &opts).unwrap();
    let stats = server.shutdown().unwrap();
    let bounds = scenario::transfer_bounds(&manifest, &["nano", "micro"]).unwrap();
    let violations = check_invariants(&out, &stats, 4, &bounds);
    assert!(violations.is_empty(), "overload violations: {violations:?}");
    assert_eq!(out.accepted + out.busy_rejected, n);
    assert!(out.max_in_flight <= 4);
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// Poisson-burst replay under default settings: the bread-and-butter
/// bursty case completes everything it accepts and the ledger, server
/// counters, and stream accounting all agree.
#[test]
fn poisson_burst_invariants_hold() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (shape, manifest) = shape_of(&artifacts);
    let run_dir = seed_run_dir(&artifacts, "burst");
    let cfg = base_cfg(artifacts, run_dir.clone());
    let queue_cap = cfg.queue_cap as u64;
    let server = Server::start(cfg).unwrap();
    let trace = gen_poisson_burst(0xB0257, 24, shape);
    let out = replay(&server, &trace, &ReplayOpts::default()).unwrap();
    let stats = server.shutdown().unwrap();
    let bounds = scenario::transfer_bounds(&manifest, &["nano", "micro"]).unwrap();
    let violations = check_invariants(&out, &stats, queue_cap, &bounds);
    assert!(violations.is_empty(), "poisson-burst violations: {violations:?}");
    assert_eq!(out.done, 24, "no deadlines or cancels: everything completes");
    assert_eq!(out.stream_mismatch, 0);
    assert!(out.tokens_streamed > 0);
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// Invariant checking itself never panics on degenerate inputs — the
/// empty replay (nothing accepted) is a legal outcome.
#[test]
fn empty_replay_is_invariant_clean() {
    let out = scenario::ReplayOutcome::default();
    let v = check_invariants(
        &out,
        &empty_stats(),
        1,
        &TransferBounds::default(),
    );
    assert!(v.is_empty(), "{v:?}");
}

fn empty_stats() -> hybrid_llm::serve::ServerStats {
    use hybrid_llm::metrics::RoutingCounters;
    hybrid_llm::serve::ServerStats {
        in_flight: 0,
        router_latency: Default::default(),
        e2e_latency: Default::default(),
        tiers: Vec::new(),
        routing: RoutingCounters::two_tier().snapshot(),
        decode_steps: 0,
        decode_slot_steps: 0,
        decode_h2d_bytes: 0,
        decode_d2h_bytes: 0,
        admit_h2d_bytes: 0,
        admit_d2h_bytes: 0,
        admissions: 0,
        admitted: 0,
        admit_latency: Default::default(),
        prefix_hit_rate: 0.0,
        prefix_shared_tokens: 0,
        prefill_tokens: 0,
        kv_blocks_utilization: 0.0,
        failovers: 0,
        degraded: 0,
        retries: 0,
        worker_deaths: 0,
        breaker_state: Vec::new(),
        hybrid_requests: 0,
        draft_tokens: 0,
        draft_accepted: 0,
        draft_local_accepted: 0,
        verify_calls: 0,
        hybrid_emitted: 0,
        hybrid_degraded_blocks: 0,
        draft_accept_rate: 0.0,
        large_call_fraction: 0.0,
        large_slot_steps: 0,
        pool_exhausted_requeues: 0,
        queue_delay: Default::default(),
        brownout_level: 0,
        class_admitted: [0; hybrid_llm::policy::PRIORITY_CLASSES],
        class_shed: [0; hybrid_llm::policy::PRIORITY_CLASSES],
        effective_quality_delta: 0.0,
    }
}

/// The hybrid-decode scenario: token-level draft–verify under mixed
/// quality targets and budgets. Gated on exactly the same invariants as
/// every other scenario plus the hybrid token ledger; on artifacts that
/// predate `verify@K` the server falls back to routed decoding and the
/// run must report zero hybrid traffic.
#[test]
fn hybrid_decode_scenario_invariants_hold() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (shape, manifest) = shape_of(&artifacts);
    let hybrid_capable = manifest.has_verify("micro") && manifest.has_paged_kv("nano");
    let run_dir = seed_run_dir(&artifacts, "hybdec");
    let mut cfg = base_cfg(artifacts, run_dir.clone());
    cfg.decode = hybrid_llm::serve::DecodeMode::Hybrid;
    let queue_cap = cfg.queue_cap as u64;
    let server = Server::start(cfg).unwrap();
    let trace = scenario::gen_hybrid_decode(0x5BEC, 24, shape);
    let out = replay(&server, &trace, &ReplayOpts::default()).unwrap();
    let stats = server.shutdown().unwrap();
    let bounds = scenario::transfer_bounds(&manifest, &["nano", "micro"]).unwrap();
    let violations = check_invariants(&out, &stats, queue_cap, &bounds);
    assert!(violations.is_empty(), "hybrid-decode violations: {violations:?}");
    assert_eq!(out.done + out.failed + out.cancelled, out.accepted);
    if hybrid_capable {
        assert!(stats.hybrid_requests > 0, "hybrid-capable artifacts, no hybrid admissions");
        assert!(stats.draft_tokens > 0, "no tokens drafted");
        assert!(stats.verify_calls > 0, "no verify calls");
        assert!(
            stats.draft_accepted + stats.draft_local_accepted <= stats.draft_tokens,
            "ledger: accepted {} + local {} > drafted {}",
            stats.draft_accepted,
            stats.draft_local_accepted,
            stats.draft_tokens
        );
    } else {
        assert_eq!(stats.hybrid_requests, 0, "pre-verify artifacts must fall back to routed");
    }
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// The overload-brownout scenario (PR 10 acceptance): 3× sustained load
/// with mixed priorities against an armed controller. Zero lost requests
/// (graceful degradation, not rejection), interactive goodput holds the
/// floor while the lower classes absorb the shedding, the controller
/// actually engages, and the level recovers to 0 once the burst drains.
#[test]
fn overload_brownout_scenario_invariants_hold() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (shape, manifest) = shape_of(&artifacts);
    let run_dir = seed_run_dir(&artifacts, "brownout");
    let sc = scenario::overload_suite().into_iter().next().unwrap();
    let mut cfg = base_cfg(artifacts, run_dir.clone());
    if let Some(cap) = sc.queue_cap {
        cfg.queue_cap = cap;
    }
    cfg.brownout_target = sc.brownout_target;
    assert!(cfg.brownout_target.is_some(), "the suite must arm the controller");
    let queue_cap = cfg.queue_cap as u64;
    let server = Server::start(cfg).unwrap();
    let trace = (sc.make)(0xB40B40, 64, shape);
    let opts = ReplayOpts { retry_busy: sc.retry_busy, ..Default::default() };
    let out = replay(&server, &trace, &opts).unwrap();
    let stats = server.shutdown().unwrap();
    let bounds = scenario::transfer_bounds(&manifest, &["nano", "micro"]).unwrap();
    let mut violations = check_invariants(&out, &stats, queue_cap, &bounds);
    violations.extend(scenario::check_brownout_invariants(&out, &stats));
    assert!(violations.is_empty(), "overload-brownout violations: {violations:?}");
    // zero lost: every accepted request reached exactly one terminal
    assert_eq!(out.done + out.failed + out.cancelled, out.accepted, "lost requests");
    assert_eq!(stats.brownout_level, 0, "level must walk back to 0 after the drain");
    assert!(
        out.interactive_goodput() >= scenario::INTERACTIVE_GOODPUT_FLOOR,
        "interactive goodput {} under the floor",
        out.interactive_goodput()
    );
    // the burst carries quality 0.9 against an L1 cap of 0.7: if the
    // controller engaged, some requests routed at a reduced target
    assert!(stats.effective_quality_delta > 0.0, "the controller never engaged");
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// Regression (satellite of the failover PR): a worker that panics
/// mid-decode with *no* retry budget must still deliver exactly one
/// terminal event to every accepted request — before the supervisor
/// landed, panicked workers silently orphaned their in-flight requests
/// until `Server::shutdown`.
#[test]
fn panicking_worker_never_orphans_requests() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (shape, manifest) = shape_of(&artifacts);
    let run_dir = seed_run_dir(&artifacts, "panic");
    let mut cfg = base_cfg(artifacts, run_dir.clone());
    // zero budget: every orphan must fail terminally *now*, not requeue
    cfg.retry_budget = 0;
    cfg.fault_plan = Some(FaultPlan::new(vec![
        Fault { tier: 0, replica: 0, at_step: 1, kind: FaultKind::Crash },
        Fault { tier: 1, replica: 0, at_step: 1, kind: FaultKind::Crash },
    ]));
    let queue_cap = cfg.queue_cap as u64;
    let server = Server::start(cfg).unwrap();
    let trace = scenario::gen_steady(0xDEADBEE, 16, shape);
    let out = replay(&server, &trace, &ReplayOpts::default()).unwrap();
    let stats = server.shutdown().unwrap();
    let bounds = scenario::transfer_bounds(&manifest, &["nano", "micro"]).unwrap();
    let violations = check_invariants(&out, &stats, queue_cap, &bounds);
    assert!(violations.is_empty(), "panicking-worker violations: {violations:?}");
    // exactly one terminal per accepted request, and the crash really
    // fired: whichever tier was decoding died holding work
    assert_eq!(out.done + out.failed + out.cancelled, out.accepted);
    assert!(stats.worker_deaths > 0, "the injected crash never fired");
    assert!(out.failed > 0, "orphans with no retry budget must fail terminally");
    assert_eq!(stats.routing.failed_total(), out.failed as u64);
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// Run one chaos-suite spec against real artifacts; `None` when the
/// artifacts aren't built (the test then skips).
fn run_chaos(name: &str, tag: &str) -> Option<(scenario::ReplayOutcome, ServerStats, Vec<String>)> {
    let artifacts = artifacts_dir()?;
    let (shape, manifest) = shape_of(&artifacts);
    let run_dir = seed_run_dir(&artifacts, tag);
    let sc = scenario::chaos_suite()
        .into_iter()
        .find(|s| s.name == name)
        .unwrap_or_else(|| panic!("no chaos spec named {name}"));
    let mut cfg = base_cfg(artifacts, run_dir.clone());
    cfg.fault_plan = Some((sc.plan)());
    cfg.decode_timeout = sc.decode_timeout;
    cfg.retry_budget = sc.retry_budget;
    let queue_cap = cfg.queue_cap as u64;
    let server = Server::start(cfg).unwrap();
    let trace = (sc.make)(0x7EA5E7, 24, shape);
    let out = replay(&server, &trace, &ReplayOpts::default()).unwrap();
    let stats = server.shutdown().unwrap();
    let bounds = scenario::transfer_bounds(&manifest, &["nano", "micro"]).unwrap();
    let violations = check_invariants(&out, &stats, queue_cap, &bounds);
    let _ = std::fs::remove_dir_all(&run_dir);
    Some((out, stats, violations))
}

/// Chaos: a large-tier replica crash mid-decode (plus one injected
/// admission error) requeues or fails every request it held — no
/// terminal-less requests, balanced counters.
#[test]
fn chaos_crash_mid_decode_invariants_hold() {
    let Some((out, stats, violations)) = run_chaos("chaos_crash", "crash") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    assert!(violations.is_empty(), "chaos_crash violations: {violations:?}");
    assert_eq!(out.done + out.failed + out.cancelled, out.accepted);
    assert!(stats.worker_deaths > 0, "the injected crash never fired");
}

/// Chaos: a frozen replica (600 ms stall against a 150 ms decode
/// timeout) is contained — the stall monitor flags it, traffic routes
/// around, and once it thaws every queued request still resolves.
#[test]
fn chaos_stalled_replica_invariants_hold() {
    let Some((out, stats, violations)) = run_chaos("chaos_stall", "stall") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    assert!(violations.is_empty(), "chaos_stall violations: {violations:?}");
    assert_eq!(out.done + out.failed + out.cancelled, out.accepted);
    // a stall is not a death: the loop thaws and keeps serving
    assert_eq!(stats.worker_deaths, 0);
}

/// Pinning (the PR's headline): a whole-large-tier outage *degrades*
/// requests onto the small tier instead of failing them — `degraded >
/// 0`, zero lost, zero failed — and the tier heals afterwards (the
/// breaker's half-open probe; final state not asserted, it races the
/// drain).
#[test]
fn tier_outage_degrades_to_small_tier_with_zero_lost() {
    let Some((out, stats, violations)) = run_chaos("chaos_tier_outage", "outage") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    assert!(violations.is_empty(), "chaos_tier_outage violations: {violations:?}");
    // zero lost: every accepted request reached exactly one terminal
    assert_eq!(out.done + out.failed + out.cancelled, out.accepted, "lost requests");
    // repeated crashes tripped the breaker (3 consecutive failures)...
    assert!(stats.worker_deaths >= 3, "only {} deaths", stats.worker_deaths);
    // ...and the outage degraded traffic to the small tier rather than
    // failing it: the paper's quality knob absorbing a fault
    assert!(stats.degraded > 0, "no requests degraded to the small tier");
    assert!(stats.retries > 0, "orphans should have requeued");
    assert_eq!(out.failed, 0, "degradation, not failure");
    assert!(stats.routing.tiers[0].routed > 0, "small tier saw no traffic");
    let _ = &stats.breaker_state; // shape only; final state races the drain
}
