//! Artifact-free property suite over the coordinator's pure logic:
//! seeded-random cases for routing/batching/label/stat invariants that
//! must hold for *any* input, not just the unit-test examples.

use hybrid_llm::corpus::{self, Scale};
use hybrid_llm::io::Tensor;
use hybrid_llm::labels::{self, QualitySamples};
use hybrid_llm::policy;
use hybrid_llm::rng::Rng;
use hybrid_llm::stats;
use hybrid_llm::testing::check;

fn rand_quality(rng: &mut Rng, n: usize, ns: usize) -> QualitySamples {
    QualitySamples::new(
        (0..n)
            .map(|_| (0..ns).map(|_| -(rng.next_f32() * 6.0)).collect())
            .collect(),
    )
}

#[test]
fn labels_are_probabilities_and_monotone_in_t() {
    check("labels in [0,1], monotone in t", 60, |rng| {
        let n = rng.range(1, 40);
        let ns = rng.range(1, 6);
        let qs = rand_quality(rng, n, ns);
        let ql = rand_quality(rng, n, ns);
        let t1 = rng.next_f32() * 2.0;
        let t2 = t1 + rng.next_f32();
        let y1 = labels::y_trans(&qs, &ql, t1).unwrap();
        let y2 = labels::y_trans(&qs, &ql, t2).unwrap();
        for (a, b) in y1.iter().zip(&y2) {
            assert!((0.0..=1.0).contains(a));
            assert!(b >= a, "monotone violated: {a} > {b}");
        }
    });
}

#[test]
fn tstar_objective_never_below_t0() {
    check("J(t*) >= J(0)", 30, |rng| {
        let n = rng.range(4, 50);
        let ns = rng.range(1, 5);
        let qs = rand_quality(rng, n, ns);
        let ql = rand_quality(rng, n, ns);
        let s = labels::find_tstar(&qs, &ql, 21).unwrap();
        let j0 = labels::pairwise_mean_abs_diff(&labels::y_prob(&qs, &ql).unwrap());
        let jstar =
            labels::pairwise_mean_abs_diff(&labels::y_trans(&qs, &ql, s.tstar).unwrap());
        assert!(jstar >= j0 - 1e-12);
    });
}

#[test]
fn tradeoff_extremes_equal_baselines() {
    check("tradeoff(0)=all-large, tradeoff(1)=all-small", 50, |rng| {
        let n = rng.range(2, 60);
        let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let qs: Vec<f64> = (0..n).map(|_| -(rng.next_f64() * 5.0)).collect();
        let ql: Vec<f64> = (0..n).map(|_| -(rng.next_f64() * 5.0)).collect();
        let p0 = policy::tradeoff_at(&scores, &qs, &ql, 0.0);
        assert!((p0.quality - stats::mean(&ql)).abs() < 1e-9);
        assert!(p0.drop_pct.abs() < 1e-9);
        let p1 = policy::tradeoff_at(&scores, &qs, &ql, 1.0);
        assert!((p1.quality - stats::mean(&qs)).abs() < 1e-9);
    });
}

#[test]
fn tradeoff_cost_advantage_is_exact() {
    check("achieved cost advantage == target fraction", 50, |rng| {
        let n = rng.range(10, 200);
        let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let q: Vec<f64> = vec![-1.0; n];
        for k in 0..=4 {
            let target = k as f64 / 4.0;
            let p = policy::tradeoff_at(&scores, &q, &q, target);
            let expect = (target * n as f64).round() / n as f64;
            assert!((p.achieved_cost_advantage - expect).abs() < 1e-9);
        }
    });
}

#[test]
fn perfect_router_never_beaten_by_random() {
    check("oracle scores dominate random routing", 25, |rng| {
        let n = rng.range(20, 100);
        let ql: Vec<f64> = (0..n).map(|_| -(rng.next_f64() * 2.0)).collect();
        // small is strictly worse by a random margin; oracle score = -margin
        let margins: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let qs: Vec<f64> = ql.iter().zip(&margins).map(|(q, m)| q - m).collect();
        let oracle: Vec<f32> = margins.iter().map(|&m| 1.0 - m as f32).collect();
        let random: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        for k in 1..4 {
            let t = k as f64 / 4.0;
            let po = policy::tradeoff_at(&oracle, &qs, &ql, t);
            let pr = policy::tradeoff_at(&random, &qs, &ql, t);
            assert!(po.quality >= pr.quality - 1e-9);
        }
    });
}

#[test]
fn ladder_k2_reproduces_threshold_policy_bitwise() {
    // the two-tier threshold policy must be the exact K=2 special case
    // of the multi-threshold ladder: same `>=` comparison, bit for bit
    check("K=2 ladder == Policy::Threshold", 80, |rng| {
        let n = rng.range(1, 200);
        let thr = rng.next_f32();
        let mut scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        // force boundary cases: exact threshold equality and the extremes
        if n >= 4 {
            scores[0] = thr;
            scores[1] = 0.0;
            scores[2] = 1.0;
            scores[3] = f32::NAN;
        }
        let two = policy::Policy::Threshold { threshold: thr }.assign(&scores);
        let k2 = policy::TierPolicy::Ladder { thresholds: vec![thr] }.assign(&scores);
        assert_eq!(two.len(), k2.len());
        for (i, (b, t)) in two.iter().zip(&k2).enumerate() {
            assert_eq!(*t, usize::from(!*b), "query {i}: score {}", scores[i]);
        }
    });
}

#[test]
fn quality_target_never_routes_cheaper() {
    // the serving API's quality knob: for any calibrated family and any
    // fixed router score, sweeping the per-request quality target upward
    // must never move the assignment to a *cheaper* tier
    check("quality knob monotone over calibrated families", 40, |rng| {
        let k = rng.range(2, 5);
        let n = rng.range(5, 80);
        let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let q_tiers: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..n).map(|_| -(rng.next_f64() * 5.0)).collect())
            .collect();
        let costs: Vec<f64> = (0..k).map(|i| i as f64 / (k - 1) as f64).collect();
        let levels = rng.range(1, 9);
        let fam =
            hybrid_llm::calibrate::calibrate_quality_ladders(&scores, &q_tiers, &costs, levels)
                .unwrap();
        assert_eq!(fam.n_tiers(), k);
        for _ in 0..4 {
            let score = rng.next_f32();
            let mut last = 0usize;
            for j in 0..=20 {
                let q = j as f32 / 20.0;
                let t = fam.assign_one(q, score);
                assert!(t < k);
                assert!(
                    t >= last,
                    "raising quality {q} routed cheaper: tier {t} < {last} (score {score})"
                );
                last = t;
            }
        }
    });
}

#[test]
fn synthetic_family_is_monotone_too() {
    check("synthetic quality family monotone", 40, |rng| {
        let k = rng.range(1, 6);
        let levels = rng.range(1, 12);
        let fam = policy::LadderFamily::synthetic(k, levels);
        let score = rng.next_f32();
        let mut last = 0usize;
        for j in 0..=24 {
            let t = fam.assign_one(j as f32 / 24.0, score);
            assert!(t >= last);
            last = t;
        }
        // extremes anchor the family
        assert_eq!(fam.assign_one(0.0, score), 0);
        if k > 1 {
            assert_eq!(fam.assign_one(1.0, score), k - 1);
        }
    });
}

#[test]
fn nan_router_scores_never_panic_the_tradeoff_sort() {
    // regression for the partial_cmp().unwrap() panic in tradeoff_at:
    // any mix of NaN and finite scores must produce a valid point
    check("tradeoff_at total under NaN scores", 40, |rng| {
        let n = rng.range(1, 60);
        let scores: Vec<f32> = (0..n)
            .map(|_| {
                if rng.next_f64() < 0.2 {
                    f32::NAN
                } else {
                    rng.next_f32()
                }
            })
            .collect();
        let qs: Vec<f64> = (0..n).map(|_| -(rng.next_f64() * 5.0)).collect();
        let ql: Vec<f64> = (0..n).map(|_| -(rng.next_f64() * 5.0)).collect();
        let target = rng.next_f64();
        let p = policy::tradeoff_at(&scores, &qs, &ql, target);
        assert!(p.quality.is_finite());
        assert!((0.0..=1.0).contains(&p.achieved_cost_advantage));
    });
}

#[test]
fn ladder_cost_advantage_monotone_in_pivot_sweep() {
    // as the proportional-ladder pivot rises, every query's tier index
    // can only move toward more capable tiers, so the cost-weighted
    // cost advantage must degrade monotonically
    check("cost advantage non-increasing as the pivot sweeps up", 40, |rng| {
        let n = rng.range(5, 150);
        let k = rng.range(2, 6);
        let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let costs: Vec<f64> = (0..k).map(|i| i as f64 / (k - 1) as f64).collect();
        let mut last = f64::INFINITY;
        for step in 0..=24 {
            let pivot = step as f32 / 20.0; // sweeps past 1.0
            let thresholds = hybrid_llm::calibrate::ladder_from_pivot(pivot, k);
            let assign = policy::TierPolicy::Ladder { thresholds }.assign(&scores);
            let ca = policy::cost_advantage_tiers(&assign, &costs);
            assert!(
                ca <= last + 1e-12,
                "cost advantage rose from {last} to {ca} at pivot {pivot}"
            );
            last = ca;
        }
    });
}

#[test]
fn calibration_threshold_transfers_within_noise() {
    // calibrate on one seeded sample, evaluate on another from the same
    // distribution: the drop may differ but must stay bounded
    check("calibration transfer bounded", 20, |rng| {
        let gen = |rng: &mut Rng, n: usize| {
            let mut scores = Vec::new();
            let mut qs = Vec::new();
            let mut ql = Vec::new();
            for _ in 0..n {
                let easy = rng.next_f64() < 0.3;
                scores.push(if easy { 0.6 + 0.4 * rng.next_f32() } else { 0.4 * rng.next_f32() });
                ql.push(-1.0 - 0.1 * rng.next_f64());
                qs.push(if easy { -1.0 - 0.1 * rng.next_f64() } else { -3.0 - rng.next_f64() });
            }
            (scores, qs, ql)
        };
        let (s1, q1, l1) = gen(rng, 300);
        let (s2, q2, l2) = gen(rng, 300);
        let cal = hybrid_llm::calibrate::calibrate(&s1, &q1, &l1, 1.0);
        let te = hybrid_llm::calibrate::evaluate_threshold(cal.threshold, &s2, &q2, &l2);
        assert!(te.drop_pct < 6.0, "calibrated threshold fell apart: {te:?}");
    });
}

#[test]
fn corpus_references_deterministic_under_reload() {
    check("corpus tsv roundtrip via detok strings", 5, |rng| {
        let seed = rng.next_u64();
        let c = corpus::generate(seed, Scale::Smoke);
        let dir = std::env::temp_dir().join(format!(
            "hybrid_prop_corpus_{}_{seed}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.tsv");
        corpus::save(&p, &c).unwrap();
        let back = corpus::load(&p).unwrap();
        for (a, b) in c.iter().zip(&back) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.reference, b.reference);
        }
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn tensor_io_roundtrip_random_shapes() {
    check("tensor io roundtrip", 40, |rng| {
        let rank = rng.below(4);
        let dims: Vec<usize> = (0..rank).map(|_| rng.range(1, 6)).collect();
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.next_f32() * 100.0 - 50.0).collect();
        let t = Tensor::f32(dims, data);
        let dir = std::env::temp_dir().join(format!("hybrid_prop_io_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.tz");
        t.save(&p).unwrap();
        assert_eq!(Tensor::load(&p).unwrap(), t);
    });
}

#[test]
fn spearman_invariant_under_monotone_transform() {
    check("spearman(x, f(x)) == 1 for increasing f", 40, |rng| {
        let n = rng.range(3, 50);
        let mut xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 10.0).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs.dedup();
        if xs.len() < 3 {
            return;
        }
        let ys: Vec<f64> = xs.iter().map(|&x| x.exp() + x * 3.0).collect();
        let rho = stats::spearman(&xs, &ys);
        assert!((rho - 1.0).abs() < 1e-9, "{rho}");
    });
}

#[test]
fn histogram_conserves_mass() {
    check("histogram counts sum to n", 40, |rng| {
        let n = rng.range(1, 300);
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 20.0 - 10.0).collect();
        let h = stats::Histogram::build(&xs, -5.0, 5.0, rng.range(1, 12));
        assert_eq!(h.counts.iter().sum::<u64>(), n as u64);
    });
}

#[test]
fn escalation_threshold_monotone_in_quality() {
    // the hybrid decode escalation knob (DESIGN.md §12): a higher
    // quality target must never verify *less* — threshold monotone
    // nondecreasing under total_cmp, including targets outside [0, 1]
    check("escalation threshold monotone in quality", 60, |rng| {
        let n = rng.range(2, 40);
        let mut qs: Vec<f32> = (0..n).map(|_| rng.next_f32() * 1.4 - 0.2).collect();
        qs.sort_by(|a, b| a.total_cmp(b));
        let thrs: Vec<f32> = qs.iter().map(|&q| policy::escalation_threshold(q)).collect();
        for (w, t) in qs.windows(2).zip(thrs.windows(2)) {
            assert!(
                t[0].total_cmp(&t[1]) != std::cmp::Ordering::Greater,
                "threshold fell from {} to {} as quality rose {} -> {}",
                t[0],
                t[1],
                w[0],
                w[1]
            );
        }
        // the operational consequence: for any fixed confidence, a block
        // verified at some target stays verified at every higher target
        let conf = -(rng.next_f32() * 10.0);
        for w in qs.windows(2) {
            if policy::should_verify(w[0], conf) {
                assert!(
                    policy::should_verify(w[1], conf),
                    "raising quality {} -> {} stopped verifying conf {conf}",
                    w[0],
                    w[1]
                );
            }
        }
    });
}

#[test]
fn escalation_policy_is_nan_safe_and_pins_high_quality() {
    check("non-finite inputs always verify; quality 1 always verifies", 40, |rng| {
        let q = rng.next_f32();
        // corrupted confidence must never silently skip the large tier
        assert!(policy::should_verify(q, f32::NAN));
        assert!(policy::should_verify(q, f32::INFINITY));
        assert!(policy::should_verify(q, f32::NEG_INFINITY));
        // non-finite or saturated targets pin the threshold to +inf —
        // the always-verify regime that makes hybrid decoding
        // byte-identical to large-only greedy
        let conf = rng.next_f32() * 20.0 - 10.0;
        assert!(policy::should_verify(f32::NAN, conf));
        assert!(policy::should_verify(1.0, conf));
        assert!(policy::should_verify(2.5, conf));
        assert_eq!(policy::escalation_threshold(f32::NAN), f32::INFINITY);
        assert_eq!(policy::escalation_threshold(1.0), f32::INFINITY);
        // at the laxest target a hopeless draft still escalates, while a
        // confident one is accepted locally (the cost-saving side)
        assert!(policy::should_verify(0.0, -100.0));
        assert!(!policy::should_verify(0.0, 0.0));
    });
}

#[test]
fn resolve_verify_rederives_the_large_stream_prefix() {
    // the draft–verify pin: whatever the small tier drafts, the tokens
    // resolve_verify emits are exactly a prefix of the large model's
    // verified stream — accepted drafts matched it and the correction
    // token IS its next choice
    check("resolve_verify == verified prefix + correction", 60, |rng| {
        let nd = rng.range(0, 8);
        let drafts: Vec<i32> = (0..nd).map(|_| rng.below(8) as i32).collect();
        let verified: Vec<i32> = (0..nd + 1).map(|_| rng.below(8) as i32).collect();
        let a = hybrid_llm::hybrid::accept_len(&drafts, &verified);
        let (a2, emit) = hybrid_llm::hybrid::resolve_verify(&drafts, &verified);
        assert_eq!(a, a2);
        assert!(a <= nd);
        assert_eq!(emit, &verified[..a + 1], "emission is not a large-stream prefix");
        assert_eq!(&drafts[..a], &verified[..a], "accepted drafts diverge from large");
        if a < nd {
            assert_ne!(drafts[a], verified[a], "rejection without a mismatch");
        }
    });
}

#[test]
fn brownout_level_never_oscillates_on_steady_input() {
    // the no-oscillation contract (DESIGN.md §13): holding the sensor
    // inputs constant, the level sequence never changes direction —
    // whatever state the controller starts in
    check("steady input => monotone level sequence", 60, |rng| {
        let mut c = policy::BrownoutController::new(1.0 + rng.next_f64() * 50.0);
        // arbitrary starting state: random delay history and some ticks
        for _ in 0..rng.below(20) {
            c.observe_delay_ms(rng.next_f64() * 200.0);
            c.tick(rng.next_f64(), rng.below(2) as u64);
        }
        let depth = if rng.next_f64() < 0.3 { 0.0 } else { rng.next_f64() * 1.5 };
        let shed = if rng.next_f64() < 0.2 { 1u64 } else { 0 };
        // with constant inputs the sensed pressure is non-increasing
        // (the delay EWMA only decays), so once the level has stepped
        // down it must never step up again — the no-ringing contract
        let mut fell = false;
        let mut last = c.level();
        for _ in 0..rng.range(10, 120) {
            let l = c.tick(depth, shed);
            assert!(
                !(fell && l > last),
                "level rose after falling on constant input (depth {depth}, shed {shed})"
            );
            fell |= l < last;
            last = l;
        }
    });
}

#[test]
fn brownout_trip_is_gated_and_recovery_is_hysteretic() {
    check("trip needs a streak; recovery is slower and reaches 0", 40, |rng| {
        let mut c = policy::BrownoutController::new(1.0 + rng.next_f64() * 20.0);
        // sustained overload: the level must not move on the first hot
        // tick, must eventually saturate, and must take strictly more
        // ticks per step coming down than going up
        let mut ticks_to_max = 0u32;
        assert_eq!(c.tick(1.0, 1), 0, "a single hot tick must not trip a level");
        while c.level() < policy::BROWNOUT_MAX_LEVEL {
            c.tick(1.0, 1);
            ticks_to_max += 1;
            assert!(ticks_to_max < 1000, "sustained overload never saturated the level");
        }
        // in-band pressure holds the level indefinitely (hysteresis
        // band: depth 0.6/0.85 ≈ 0.71 is neither hot nor calm)
        for _ in 0..rng.range(1, 50) {
            assert_eq!(
                c.tick(0.6, 0),
                policy::BROWNOUT_MAX_LEVEL,
                "in-band pressure must hold the level"
            );
        }
        // load recedes: the controller must walk all the way back to 0
        // and stay there, taking longer to recover than it took to ramp
        let mut ticks_to_zero = 0u32;
        while c.level() > 0 {
            c.tick(0.0, 0);
            ticks_to_zero += 1;
            assert!(ticks_to_zero < 1000, "drained controller never recovered to 0");
        }
        assert!(
            ticks_to_zero > ticks_to_max,
            "recovery ({ticks_to_zero} ticks) must be slower than ramp-up ({ticks_to_max})"
        );
        for _ in 0..rng.range(1, 40) {
            assert_eq!(c.tick(0.0, 0), 0, "an idle controller must stay at level 0");
        }
    });
}

#[test]
fn brownout_level_monotone_in_sensed_load() {
    // two fresh controllers under constant load, one strictly heavier:
    // at every tick the heavier one's level dominates
    check("heavier load => level at least as high", 50, |rng| {
        let target = 1.0 + rng.next_f64() * 20.0;
        let mut lo = policy::BrownoutController::new(target);
        let mut hi = policy::BrownoutController::new(target);
        let d_lo = rng.next_f64() * 1.2;
        let d_hi = d_lo + rng.next_f64() * (1.5 - d_lo);
        for t in 0..rng.range(5, 150) {
            let ll = lo.tick(d_lo, 0);
            let lh = hi.tick(d_hi, 0);
            assert!(
                ll <= lh,
                "tick {t}: depth {d_lo} reached level {ll} > level {lh} at depth {d_hi}"
            );
        }
    });
}

#[test]
fn brownout_actuators_identity_at_level_0_and_monotone() {
    check("actuators: identity at 0, monotone in level", 60, |rng| {
        let q = rng.next_f32() * 1.4 - 0.2;
        let gamma = rng.below(12);
        // level 0 is the byte-identity pin: every actuator is a no-op
        assert_eq!(policy::brownout_effective_quality(0, q), q);
        assert_eq!(policy::brownout_escalation_quality(0, q), q);
        assert_eq!(policy::brownout_gamma(0, gamma), gamma);
        assert_eq!(policy::brownout_quality_cap(0), 1.0);
        let mut last_cap = f32::INFINITY;
        for level in 0..=policy::BROWNOUT_MAX_LEVEL {
            let cap = policy::brownout_quality_cap(level);
            assert!(cap <= last_cap, "quality cap rose at level {level}");
            last_cap = cap;
            assert!(policy::brownout_effective_quality(level, q) <= q.max(cap));
            assert!(policy::brownout_gamma(level, gamma) <= gamma, "brownout grew γ");
            assert!(gamma == 0 || policy::brownout_gamma(level, gamma) >= 1);
        }
    });
}

#[test]
fn admission_is_strictly_lowest_class_first() {
    // the L3 invariant: at any level and any occupancy where a lower
    // class is admitted, every higher class is admitted too — so no
    // higher-priority request is ever shed in a window where a
    // lower-priority one was admitted
    check("class caps monotone in priority at every level", 60, |rng| {
        let cap = rng.range(1, 64);
        for level in 0..=policy::BROWNOUT_MAX_LEVEL {
            let mut last = 0usize;
            for p in policy::Priority::all() {
                let c = policy::class_queue_cap(level, p, cap);
                assert!((1..=cap).contains(&c), "class cap {c} outside [1, {cap}]");
                assert!(
                    c >= last,
                    "level {level}: {} admits less than a lower class",
                    p.name()
                );
                let f = policy::admission_fraction(level, p);
                assert!((0.0..=1.0).contains(&f) && f > 0.0);
                last = c;
            }
            // Interactive always keeps the full queue
            assert_eq!(
                policy::class_queue_cap(level, policy::Priority::Interactive, cap),
                cap
            );
            if level < policy::BROWNOUT_MAX_LEVEL {
                // below L3 admission is not priority-weighted at all
                for p in policy::Priority::all() {
                    assert_eq!(policy::class_queue_cap(level, p, cap), cap);
                }
            }
        }
    });
}

#[test]
fn gap_diff_antisymmetric_in_score_inversion() {
    check("inverting scores flips the gap-diff sign", 30, |rng| {
        // even n and distinct scores: the 50% split is then exactly
        // mirrored under score inversion
        let n = rng.range(5, 40) * 2;
        let mut scores: Vec<f32> = (0..n).map(|i| i as f32).collect();
        rng.shuffle(&mut scores);
        let gap: Vec<f64> = (0..n).map(|_| rng.next_f64() * 4.0 - 2.0).collect();
        let inv: Vec<f32> = scores.iter().map(|&s| -s).collect();
        let d = hybrid_llm::eval::gap_diff(&scores, &gap, 0.5);
        let di = hybrid_llm::eval::gap_diff(&inv, &gap, 0.5);
        assert!((d + di).abs() < 1e-6, "{d} vs {di}");
    });
}
